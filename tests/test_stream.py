"""Open-loop streaming serving: arrivals, admission control, latency SLOs,
and reactive autoscaling — at timing scale with stub engines.

Covers the full stack top-down:

  runtime   grains *arrive* (ArrivalSource): join-the-homogenized-shortest-
            queue admission, bounded per-replica depth, shed-or-backlog
            overflow, workload events rejected at the execution plane,
  fleet     serve_stream traces (enqueue/first-token/completion), shed
            records, LatencyStats percentiles, the metrics->membership loop
            (scale rules joining replicas on a measured p99 breach), and the
            per-replica wave-quota fix,
  scenario  workload-clause grammar (arrive/burst/mix/scale) round-trips,
            bitwise-deterministic seeded arrivals, phase-relative anchoring,
  cluster   the facade's open-loop route, pool sizing, mix shifts, and the
            actionable rejections (sim/train refuse workload scenarios;
            scale rules need an engine factory).
"""

import math

import pytest
from stub_engine import StubEngine, expected_tokens, mk_requests

from repro.cluster import (
    Cluster,
    FleetSpec,
    ScaleRule,
    Scenario,
    ServeJob,
    TrainJob,
    materialize_workload,
)
from repro.core import (
    ArrivalSource,
    AsyncRuntime,
    PerformanceTracker,
    PerfReport,
    SimWorker,
    TimelineEvent,
)
from repro.serve import FleetServer, Replica


def mk_runtime(perfs):
    workers = [SimWorker(f"w{i}", float(p)) for i, p in enumerate(perfs)]
    tracker = PerformanceTracker(alpha=0.5)
    for w in workers:
        tracker.observe(PerfReport(w.name, w.perf, 1.0, 0.0))
    return workers, AsyncRuntime(workers, tracker=tracker)


def mk_server(specs, **kw):
    """specs: list of (name, perf, max_batch)."""
    replicas = [Replica(n, p) for n, p, _ in specs]
    engines = {n: StubEngine(max_batch=b, name=n) for n, _, b in specs}
    return FleetServer(replicas, engines, **kw)


def stub_factory(spec):
    # Duck-typed over both factory seams: FleetServer passes a Replica
    # (no concurrency), Cluster passes a WorkerSpec.
    return StubEngine(max_batch=getattr(spec, "concurrency", 2),
                      max_seq=64, name=spec.name)


# ================================================================== runtime
def test_arrivals_complete_and_record_times():
    _, rt = mk_runtime([2.0, 1.0])
    res = rt.run(6, grain_cost=1.0, arrivals=[0.0, 0.5, 1.0, 1.5, 2.0, 2.5])
    assert len(res.values) == 6 and not res.shed
    assert res.arrive_s == {g: 0.5 * g for g in range(6)}
    # An arrival can never finish before it arrives.
    for rec in res.records:
        assert rec.end_s >= res.arrive_s[rec.grain]


def test_arrivals_favor_fast_worker():
    """Admission is join-the-homogenized-shortest-queue: with a 3x perf
    spread, the fast worker absorbs most of a simultaneous burst."""
    _, rt = mk_runtime([3.0, 1.0])
    res = rt.run(8, grain_cost=1.0, arrivals=[0.0] * 8)
    shares = res.shares()
    assert shares["w0"] > shares["w1"]


def test_backlog_drains_when_queues_free():
    """overflow='queue': arrivals beyond every queue's depth wait runtime-
    side and are admitted as slots free — nothing is lost."""
    _, rt = mk_runtime([1.0, 1.0])
    res = rt.run(12, grain_cost=1.0, arrivals=[0.0] * 12, max_queue_depth=2)
    assert len(res.values) == 12 and not res.shed


def test_shed_records_explicit_rejects():
    _, rt = mk_runtime([1.0])
    res = rt.run(8, grain_cost=4.0, arrivals=[0.0] * 8,
                 max_queue_depth=1, overflow="shed")
    assert res.shed, "a depth-1 queue under an 8-grain burst must shed"
    assert len(res.values) + len(res.shed) == 8
    # Shed grains still have their arrival recorded (the reject trace).
    for g in res.shed:
        assert g in res.arrive_s
        assert g not in res.values


def test_arrival_validation():
    _, rt = mk_runtime([1.0, 1.0])
    with pytest.raises(ValueError, match="initial_plan"):
        rt.run(2, arrivals=[0.0, 0.0], initial_plan=rt.plan(2))
    with pytest.raises(ValueError, match="overflow"):
        rt.run(2, arrivals=[0.0, 0.0], overflow="drop")
    with pytest.raises(ValueError, match="max_queue_depth"):
        rt.run(2, grain_cost=1.0, max_queue_depth=2)
    with pytest.raises(ValueError, match="covers 1"):
        rt.run(3, arrivals=[0.0])
    with pytest.raises(ValueError):
        ArrivalSource([-1.0])


def test_runtime_rejects_workload_plane_events():
    """arrive/mix TimelineEvents are consumed by the serving layer; feeding
    them to the execution plane is a usage error with an actionable hint."""
    _, rt = mk_runtime([1.0])
    ev = TimelineEvent(0.0, "arrive", (0.0, 1.0))
    with pytest.raises(ValueError, match="workload-plane"):
        rt.run(2, grain_cost=1.0, timeline=(ev,), timeline_relative=True)


# ========================================================= wave-quota fix
def test_wave_plan_caps_per_replica_initial_queue():
    """The old wave quota was global (depth x live count): a fast replica
    could be handed nearly the whole wave and start it deeper than
    max_queue_depth.  The plan cap enforces the depth per replica."""
    server = mk_server([("fast", 8.0, 2), ("slow", 1.0, 2)],
                       max_queue_depth=4)
    now = server.dispatcher.clock
    server.tracker.rejoin("fast", 8.0, now)
    server.tracker.rejoin("slow", 1.0, now)
    uncapped = server.dispatcher.runtime.plan(8)
    by_name = dict(zip(uncapped.workers, uncapped.shares))
    assert by_name["fast"] > 4, "precondition: the homogenized share must breach the cap"
    capped = server._wave_plan(8)
    assert capped is not None
    shares = dict(zip(capped.workers, capped.shares))
    assert all(s <= 4 for s in shares.values())
    assert sum(shares.values()) == 8
    assert shares["slow"] == 4  # the excess lands on the replica with room


def test_wave_plan_no_cap_is_bitwise_identical_path():
    """Equal perfs never breach the cap: _wave_plan must return None so the
    closed-loop wave path (and its plans) stay exactly as before."""
    server = mk_server([("a", 2.0, 2), ("b", 2.0, 2)], max_queue_depth=4)
    assert server._wave_plan(8) is None


def test_wave_serve_respects_per_replica_depth():
    """End-to-end: with a 8x perf spread, every wave's *initial* admission
    must still respect max_queue_depth per replica (the capped plan), and
    all requests decode exactly once."""
    server = mk_server([("fast", 8.0, 2), ("slow", 1.0, 2)],
                       max_queue_depth=3)
    now = server.dispatcher.clock
    server.tracker.rejoin("fast", 8.0, now)
    server.tracker.rejoin("slow", 1.0, now)
    reqs = mk_requests(6, max_new=4)
    rep = server.serve(reqs)
    assert rep.n_requests == 6
    for r in reqs:
        assert r.out_tokens == expected_tokens(r)


# ============================================================ serve_stream
def test_stream_traces_and_latency_stats():
    server = mk_server([("r0", 4.0, 2), ("r1", 2.0, 2)], max_queue_depth=8)
    reqs = mk_requests(10, max_new=4)
    arrive = [0.5 * i for i in range(10)]
    rep = server.serve_stream(reqs, arrive)
    assert rep.n_served == 10 and rep.n_shed == 0
    assert len(rep.traces) == 10
    for t, a in zip(rep.traces, arrive):
        assert t.arrive_s == a
        assert t.first_token_s is not None and t.first_token_s >= a
        assert t.finish_s >= t.first_token_s
        assert t.ttft_s >= 0 and t.latency_s > 0
    lat = rep.latency
    assert math.isfinite(lat.p50_ttft_s) and math.isfinite(lat.p99_ttft_s)
    assert lat.p50_ttft_s <= lat.p99_ttft_s
    # Exactly-once decode under streaming admission.
    for r in reqs:
        assert r.out_tokens == expected_tokens(r)


def test_stream_shed_traces_on_overflow():
    server = mk_server([("r0", 1.0, 1)], max_queue_depth=1)
    reqs = mk_requests(8, max_new=6)
    rep = server.serve_stream(reqs, [0.0] * 8, overflow="shed")
    assert rep.n_shed > 0
    assert rep.n_served + rep.n_shed == 8
    assert rep.shed_rate == pytest.approx(rep.n_shed / 8)
    for t in rep.traces:
        if t.shed:
            assert t.first_token_s is None and t.finish_s is None
            assert t.worker is None and t.tokens == 0
    assert rep.latency.n_shed == rep.n_shed


def test_stream_goodput_under_deadline():
    server = mk_server([("r0", 4.0, 2)], max_queue_depth=8)
    reqs = mk_requests(6, max_new=4)
    rep = server.serve_stream(reqs, [i * 0.5 for i in range(6)],
                              deadline_s=1e9)
    assert rep.latency.n_within_deadline == 6
    assert rep.latency.goodput_rps > 0


def test_stream_autoscale_joins_and_serves():
    """The reactive loop end-to-end: a breached p99-TTFT rule joins a
    replica mid-stream (engine lazily built) and that replica takes work."""
    server = mk_server([("r0", 2.0, 2)], max_queue_depth=2,
                       engine_factory=stub_factory)
    reqs = mk_requests(30, max_new=6)
    rule = ScaleRule(add=1, metric="p99", threshold=0.01, window=4)
    rep = server.serve_stream(reqs, [0.2 * i for i in range(30)],
                              scale_rules=[rule])
    assert rep.joined == ("scale0",)
    assert rep.shares.get("scale0", 0) > 0
    assert "scale0" in rep.worker_busy
    for r in reqs:
        assert r.out_tokens == expected_tokens(r)


def test_stream_scale_rule_not_breached_never_joins():
    server = mk_server([("r0", 8.0, 4)], max_queue_depth=8,
                       engine_factory=stub_factory)
    reqs = mk_requests(6, max_new=3)
    rule = ScaleRule(add=1, metric="p99", threshold=1e9, window=2)
    rep = server.serve_stream(reqs, [2.0 * i for i in range(6)],
                              scale_rules=[rule])
    assert rep.joined == ()


def test_scale_rules_require_engine_factory():
    server = mk_server([("r0", 2.0, 2)], max_queue_depth=4)
    rule = ScaleRule(add=1, metric="p99", threshold=0.1)
    with pytest.raises(ValueError, match="engine_factory"):
        server.serve_stream(mk_requests(4), [0.0] * 4, scale_rules=[rule])


def test_stream_survives_mid_stream_halve():
    """The acceptance shape: a mid-stream perf halving migrates load and the
    survivors still homogenize (quality <= 1.3)."""
    server = mk_server([("r0", 4.0, 2), ("r1", 4.0, 2)], max_queue_depth=8)
    now = server.dispatcher.clock
    for n in ("r0", "r1"):
        server.tracker.rejoin(n, 8.0, now)  # rate units: perf x slots
    reqs = mk_requests(24, max_new=6)
    halve = TimelineEvent(2.0, "perf", "r0", perf=2.0)
    rep = server.serve_stream(reqs, [0.1 * i for i in range(24)],
                              timeline=(halve,))
    assert rep.n_shed == 0
    assert rep.quality <= 1.3
    for r in reqs:
        assert r.out_tokens == expected_tokens(r)


# ============================================== scenario workload grammar
FLEET = FleetSpec.parse("w0=4x2,w1=2x2")


def test_workload_clause_round_trip():
    s = "arrive:poisson(8)@0-30;burst:64@10;mix:len*1.5@12;scale:+2@p99>0.5"
    sc = Scenario.parse(s)
    assert str(Scenario.parse(str(sc))) == str(sc)
    assert sc.has_workload
    assert sc.scale_rules == (ScaleRule(add=2, metric="p99", threshold=0.5),)


def test_workload_clauses_split_on_whitespace():
    sc = Scenario.parse("arrive:poisson(8)@0-30 burst:64@10 scale:+2@p99>0.5")
    assert len(sc.clauses) == 2 and len(sc.scale_rules) == 1


def test_workload_grammar_rejections():
    for bad in (
        "arrive:uniform(8)@0-30",     # only poisson processes
        "burst:0@10",                 # empty burst
        "mix:len*0@12",               # non-positive factor
        "scale:+0@p99>0.5",           # must add at least one replica
        "scale:+1@p200>0.5",          # not a percentile
        "scale:+1@p99>0",             # non-positive threshold
    ):
        with pytest.raises(ValueError):
            Scenario.parse(bad)


def test_arrivals_bitwise_deterministic_by_seed():
    sc = Scenario.parse("arrive:poisson(8)@0-10;burst:4@2")
    a = sc.compile(FLEET, phase_s=10.0, seed=5)
    b = sc.compile(FLEET, phase_s=10.0, seed=5)
    assert a == b, "same seed must materialize bitwise-identical arrivals"
    c = sc.compile(FLEET, phase_s=10.0, seed=6)
    assert a != c


def test_phase_relative_arrive_anchors_to_true_phase_start():
    """arrive:poisson(L)@1:50% with no '-T2' spans one phase estimate from
    the *true* window-1 start — the satellite's phase-relative case."""
    sc = Scenario.parse("arrive:poisson(4)@1:50%")
    assert sc.needs_estimates
    sched = sc.schedule(FLEET, phase_s=10.0, seed=3)
    assert sched.phase_events(0, 0.0) == ()
    evs = sched.phase_events(1, 12.0)
    assert len(evs) == 1 and evs[0].kind == "arrive"
    assert evs[0].time_s == pytest.approx(12.0 + 5.0)
    assert all(off >= 0 for off in evs[0].worker)
    assert sched.exhausted


def test_materialize_workload_splits_planes():
    sc = Scenario.parse("arrive:poisson(6)@0-5;mix:len*2@3;halve:w0@1")
    plan = materialize_workload(sc.schedule(FLEET, phase_s=5.0, seed=1), 5.0)
    assert plan.n_requests == len(plan.arrive_s) > 0
    assert list(plan.arrive_s) == sorted(plan.arrive_s)
    assert plan.mix == ((3.0, 2.0),)
    assert plan.lengths_factor(2.9) == 1.0
    assert plan.lengths_factor(3.0) == 2.0
    assert [e.kind for e in plan.timeline] == ["perf"]


# ================================================================= cluster
def test_cluster_serve_open_loop_end_to_end():
    cl = Cluster("w0=4x2,w1=2x2", priors="spec", seed=3)
    pool = mk_requests(200, max_new=6)
    rep = cl.serve(
        ServeJob(pool, engine_factory=stub_factory, max_queue_depth=4,
                 overflow="shed", deadline_s=5.0),
        scenario="arrive:poisson(8)@0-10 burst:16@2 halve:w0@1:0% "
                 "scale:+1@p99>0.2/10",
    )
    assert rep.kind == "serve"
    assert rep.metrics["mode"] == "open-loop"
    assert rep.n_phases == 1 and rep.phases[0].label == "stream"
    lat = rep.latency
    assert lat is not None
    assert math.isfinite(lat.p50_ttft_s) and math.isfinite(lat.p99_ttft_s)
    assert rep.metrics["n_requests"] < len(pool)  # arrivals sized the stream
    # The autoscaled replica joined AND shows up in the unified timelines.
    assert rep.metrics["joined"] == ["scale0"]
    assert rep.worker_timelines["scale0"].n_grains > 0
    assert "latency[" in rep.summary()


def test_cluster_serve_wave_mode_unchanged_without_workload():
    cl = Cluster("w0=4x2,w1=2x2")
    rep = cl.serve(ServeJob(mk_requests(8, max_new=4),
                            engine_factory=stub_factory))
    assert rep.latency is None
    assert all(p.label == "wave" for p in rep.phases)
    assert "mode" not in rep.metrics


def test_cluster_serve_mix_scales_late_arrivals():
    cl = Cluster("w0=4x2", priors="spec")
    pool = mk_requests(100, max_new=4)
    rep = cl.serve(
        ServeJob(pool, engine_factory=stub_factory, window_s=4.0),
        scenario="arrive:poisson(4)@0-8 mix:len*2@4",
    )
    served = rep.artifact
    assert any(r.max_new_tokens == 8 for r in served), \
        "requests arriving after the mix shift must carry the scaled budget"
    assert any(r.max_new_tokens == 4 for r in served)


def test_cluster_serve_pool_smaller_than_arrivals_is_actionable():
    cl = Cluster("w0=4x2", priors="spec")
    with pytest.raises(ValueError, match="request pool"):
        cl.serve(
            ServeJob(mk_requests(3, max_new=4), engine_factory=stub_factory),
            scenario="arrive:poisson(50)@0-10",
        )


def test_simulate_and_train_reject_workload_scenarios():
    cl = Cluster("w0=2,w1=1")
    with pytest.raises(ValueError, match="Cluster.serve"):
        cl.simulate(100, scenario="arrive:poisson(2)@0-5")
    with pytest.raises(ValueError, match="Cluster.serve"):
        cl.simulate(100, scenario="scale:+1@p99>0.5")
    with pytest.raises(ValueError, match="Cluster.serve"):
        cl.train(TrainJob(model=None, steps=1),
                 scenario="burst:8@1")
