"""Launcher CLI smoke tests (subprocess, real entry points).

Marked ``slow``: each test boots a fresh interpreter + JAX (the dryrun cell
additionally compiles against a 512-device host mesh), so the module is
excluded from the default tier-1 run (see pytest.ini) and exercised with
``pytest -m slow``.
"""

import os
import subprocess
import sys

import pytest

pytestmark = pytest.mark.slow

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", *args], capture_output=True, text=True,
        env=env, cwd=ROOT, timeout=timeout,
    )


def test_train_cli_single():
    out = _run(["repro.launch.train", "--arch", "qwen2-1.5b", "--steps", "6",
                "--batch", "2", "--seq", "16"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "done: loss" in out.stdout


def test_train_cli_hdp():
    out = _run(["repro.launch.train", "--mode", "hdp", "--arch", "qwen2-1.5b",
                "--steps", "6", "--seq", "16", "--grains", "4",
                "--pods", "3:1"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "plan[" in out.stdout


def test_train_cli_hdp_static_baseline():
    out = _run(["repro.launch.train", "--mode", "hdp", "--arch", "qwen2-1.5b",
                "--steps", "4", "--seq", "16", "--grains", "4",
                "--pods", "3:1", "--static"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "plan[" in out.stdout


def test_bench_hdp_cli(tmp_path):
    """Toy-scale smoke of the HDP benchmark: JSON emitted, both scenarios
    present, and the homogenized runtime beats the static plan on the step
    where the fault fires."""
    import json

    out_path = str(tmp_path / "BENCH_hdp.json")
    out = _run(["benchmarks.bench_hdp", "--grains", "64", "--steps", "4",
                "--fault-step", "2", "--out", out_path], timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    with open(out_path) as f:
        data = json.load(f)
    assert set(data["scenarios"]) == {"perf_halving", "kill"}
    for sc in data["scenarios"].values():
        assert sc["fault_step_speedup"] > 1.0
    halving = data["scenarios"]["perf_halving"]
    assert halving["adaptive"]["fault_step_quality"] <= 1.2
    assert halving["static"]["fault_step_quality"] >= 1.6


def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "qwen2-1.5b", "--requests", "4",
                "--max-new", "3", "--max-seq", "32", "--replicas", "4x2:2x1",
                "--queue-depth", "4", "--scenario", "halving"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "served 4 requests" in out.stdout
    assert "tok/s" in out.stdout


def test_bench_serve_cli(tmp_path):
    """Toy-scale smoke of the serving benchmark: JSON emitted with the
    batched-vs-serial speedup and the fault-scenario quality."""
    import json

    out_path = str(tmp_path / "BENCH_serve.json")
    out = _run(["benchmarks.bench_serve", "--requests", "12", "--max-new", "4",
                "--out", out_path], timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    with open(out_path) as f:
        data = json.load(f)
    assert data["speedup"] >= 2.0
    assert data["fault"]["worst_quality"] <= 1.3


@pytest.mark.parametrize("arch,shape", [("qwen2-1.5b", "decode_32k")])
def test_dryrun_cli_cell(arch, shape, tmp_path):
    out = _run(["repro.launch.dryrun", "--arch", arch, "--shape", shape,
                "--mesh", "single", "--out", str(tmp_path), "--no-extrapolate"],
               timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "all cells green" in out.stdout
    import json, glob

    files = glob.glob(str(tmp_path / "*.json"))
    assert len(files) == 1
    cell = json.load(open(files[0]))
    assert cell["status"] == "run" and cell["n_devices"] == 256
