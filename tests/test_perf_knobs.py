"""Perf-iteration knobs must preserve exact (or bounded-drift) semantics.

Compile-heavy (~30s of jit across knob variants): out of the tier-1 default
run, exercised via `pytest -m slow` (see pytest.ini)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.models import LayerSpec, Model, ModelConfig


def base_cfg(**kw) -> ModelConfig:
    d = dict(
        name="knobs", n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
        d_ff=64, vocab_size=60, head_dim=8, vocab_pad_to=64,
        layer_pattern=(LayerSpec("attn", "dense"),),
        param_dtype="float32", compute_dtype="float32",
        rope_theta=1e4, use_pallas=False,
    )
    d.update(kw)
    return ModelConfig(**d)


def lm_batch(b=2, s=13, v=60, seed=0):
    rng = np.random.default_rng(seed)
    t = jnp.asarray(rng.integers(0, v, (b, s)))
    return {
        "tokens": t,
        "targets": jnp.roll(t, -1, 1),
        "loss_mask": jnp.ones((b, s), jnp.float32),
    }


def test_chunked_ce_exact():
    cfg = base_cfg()
    m = Model(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch()
    l0, _ = m.loss(p, batch)
    for c in (4, 5, 13, 32):
        l1, _ = Model(dataclasses.replace(cfg, ce_chunk=c)).loss(p, batch)
        assert abs(float(l0) - float(l1)) < 1e-5, (c, float(l0), float(l1))


def test_chunked_ce_gradients_match():
    cfg = base_cfg()
    m = Model(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch()
    g0 = jax.grad(lambda p: m.loss(p, batch)[0])(p)
    g1 = jax.grad(
        lambda p: Model(dataclasses.replace(cfg, ce_chunk=4)).loss(p, batch)[0]
    )(p)
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-6)


def test_remat_policies_same_loss():
    cfg = base_cfg()
    p = Model(cfg).init(jax.random.key(0))
    batch = lm_batch()
    l_n, _ = Model(cfg).loss(p, batch)
    l_d, _ = Model(dataclasses.replace(cfg, remat_policy="dots")).loss(p, batch)
    assert abs(float(l_n) - float(l_d)) < 1e-6
    g = jax.grad(
        lambda p: Model(dataclasses.replace(cfg, remat_policy="dots")).loss(p, batch)[0]
    )(p)
    assert all(np.isfinite(np.asarray(x)).all() for x in jax.tree.leaves(g))


def _decode_all(m, p, batch, s):
    caches = m.init_cache(2, s)
    out = None
    for i in range(s):
        out, caches = m.decode_step(p, caches, batch["tokens"][:, i : i + 1], jnp.int32(i))
    return out


def test_cache_dtype_bf16_bounded_drift():
    cfg = base_cfg()
    m = Model(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch()
    ref, _ = m.logits(p, batch)
    out = _decode_all(Model(dataclasses.replace(cfg, cache_dtype="bfloat16")), p, batch, 13)
    drift = float(jnp.max(jnp.abs(out[:, 0] - ref[:, -1])))
    assert drift < 0.05, drift


def test_onehot_cache_update_exact():
    cfg = base_cfg()
    m = Model(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch()
    ref = _decode_all(m, p, batch, 13)
    out = _decode_all(Model(dataclasses.replace(cfg, cache_update="onehot")), p, batch, 13)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=1e-5, atol=1e-5
    )


def test_decode_sample_matches_argmax():
    cfg = base_cfg()
    m = Model(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch()
    logits = _decode_all(m, p, batch, 13)
    toks = _decode_all(Model(dataclasses.replace(cfg, decode_sample=True)), p, batch, 13)
    assert toks.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(toks[:, 0]), np.asarray(jnp.argmax(logits[:, 0], -1))
    )


def test_full_unroll_same_numerics():
    cfg = base_cfg()
    m = Model(cfg)
    p = m.init(jax.random.key(0))
    batch = lm_batch()
    l0, _ = m.loss(p, batch)
    l1, _ = Model(dataclasses.replace(cfg, full_unroll=True)).loss(p, batch)
    assert abs(float(l0) - float(l1)) < 1e-6


def test_grouped_gqa_vs_mha_consistency():
    """GQA with Hkv == Hq must equal plain MHA math (group size 1 path)."""
    cfg = base_cfg(n_heads=4, n_kv_heads=4)
    m = Model(cfg)
    p = m.init(jax.random.key(1))
    batch = lm_batch()
    logits, _ = m.logits(p, batch)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
