"""Validate the simulator + TDA against the paper's own experimental claims.

Paper §3 (Figs 3-6):
  F1. Equal allotment ('heterogeneous behavior'): speedup *degrades* when the
      slow 6th and 9th service-providers join.
  F2. Homogenized speedup is monotonically non-decreasing in workers.
  F3. Size 800: homogenized max beats heterogeneous max (paper: 3.6 vs 2.8).
  F4. Across sizes 200..1000: homogenized max / heterogeneous max >= ~1.4
      (paper: 5.5 vs 3.5 => 1.57; '55% increase in speedup').
  F5. Size 200 is overhead-dominated: speedup < 1 with the full fleet.
  F6. Larger loads => closer to the ideal line (Eq. 8 linearity).
  F7. Measured homogenized speedup matches Eq. 6 prediction (Fig 4).
  F8. Overhead is linear in load with recoverable slope M (Fig 5).
"""

import numpy as np
import pytest

from repro.core import (
    PAPER_MACHINES,
    ClusterSim,
    OverheadModel,
    ServiceProvider,
    TDAServer,
    ThinClient,
    overhead_slope_fit,
    predicted_speedup,
    virtual_machine_count,
)


@pytest.fixture(scope="module")
def sim():
    return ClusterSim(perfs=PAPER_MACHINES, overhead=OverheadModel(m=20.0))


def test_f1_heterogeneous_speedup_dips_on_slow_workers(sim):
    s = sim.speedup_curve(800, homogenize=False)
    assert s[5] < s[4], "6th (slow) worker must degrade equal-split speedup"
    assert s[8] < s[7], "9th (slow) worker must degrade equal-split speedup"


def test_f2_homogenized_speedup_monotone(sim):
    s = sim.speedup_curve(800, homogenize=True)
    assert all(b >= a - 1e-9 for a, b in zip(s, s[1:], strict=False)), s


def test_f3_homogenized_beats_heterogeneous_at_800(sim):
    het = max(sim.speedup_curve(800, homogenize=False))
    hom = max(sim.speedup_curve(800, homogenize=True))
    assert hom > 1.2 * het, (hom, het)
    # Same qualitative magnitudes as the paper (2.8 vs 3.6).
    assert 2.0 < het < 4.0
    assert 3.0 < hom < 6.0


def test_f4_55pct_gain_across_sizes(sim):
    het = max(
        max(sim.speedup_curve(n, homogenize=False)) for n in (200, 400, 600, 800, 1000)
    )
    hom = max(
        max(sim.speedup_curve(n, homogenize=True)) for n in (200, 400, 600, 800, 1000)
    )
    assert hom / het >= 1.4, (hom, het)


def test_f5_small_load_overhead_dominated(sim):
    # Fig 6(a): at size 200 the equal-split fleet is slower than standalone.
    s = sim.run_job(200, homogenize=False).speedup
    assert s < 1.0, f"size-200 equal-split job should not speed up (got {s})"
    # Homogenization barely rescues it (overhead still dominates).
    s_h = sim.run_job(200, homogenize=True).speedup
    assert s_h < 1.2


def test_f6_larger_loads_more_linear(sim):
    """Ratio of achieved to ideal (N_H) speedup grows with load size."""
    p_s = sim.p_standalone
    ratios = []
    for n in (200, 600, 1000):
        nh = virtual_machine_count(PAPER_MACHINES, p_s)
        ratios.append(sim.run_job(n, homogenize=True).speedup / nh)
    assert ratios[0] < ratios[1] < ratios[2]


def test_f7_formula_matches_simulation(sim):
    """Fig 4: measured homogenized speedup == Eq. 6 prediction (exact here,
    because the simulator implements the paper's cost model)."""
    for n in (400, 800, 1000):
        res = sim.run_job(n, homogenize=True)
        pred = predicted_speedup(
            sim.standalone_time(n),
            PAPER_MACHINES,
            sim.p_standalone,
            load=n,
            overhead=sim.overhead,
        )
        assert res.speedup == pytest.approx(pred, rel=0.02), (n, res.speedup, pred)


def test_f8_overhead_linear_slope_recoverable(sim):
    loads = [200, 400, 600, 800, 1000]
    ovh = [sim.run_job(n).overhead for n in loads]
    assert overhead_slope_fit(loads, ovh) == pytest.approx(20.0, rel=1e-6)


# ----------------------------------------------------------- adaptive closed loop
def test_adaptive_learning_converges_to_oracle():
    """Starting from equal priors, heartbeat-driven homogenization converges to
    the oracle-perf allotment within a few jobs."""
    sim = ClusterSim(perfs=PAPER_MACHINES)
    results = sim.run_adaptive(800, n_jobs=8)
    oracle = sim.run_job(800, homogenize=True).speedup
    assert results[-1].speedup == pytest.approx(oracle, rel=0.05)
    assert results[-1].speedup >= results[0].speedup - 1e-9


def test_adaptive_handles_jitter():
    sim = ClusterSim(perfs=PAPER_MACHINES, jitter=0.05, seed=1)
    results = sim.run_adaptive(800, n_jobs=12)
    oracle = ClusterSim(perfs=PAPER_MACHINES).run_job(800).speedup
    assert results[-1].speedup > 0.8 * oracle


# ----------------------------------------------------------------- real TDA run
def test_tda_distributed_matmul_is_exact():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((96, 64)).astype(np.float32)
    b = rng.standard_normal((64, 48)).astype(np.float32)
    providers = [ServiceProvider(f"sp{i}", p) for i, p in enumerate(PAPER_MACHINES[:5])]
    client = ThinClient(TDAServer(providers))
    out, sim_time = client.matmul(a, b)
    np.testing.assert_allclose(out, a @ b, rtol=1e-6)
    assert sim_time > 0


def test_tda_homogenized_beats_equal_split_timing():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((200, 32)).astype(np.float32)
    b = rng.standard_normal((32, 32)).astype(np.float32)

    def run(homogenize):
        providers = [
            ServiceProvider(f"sp{i}", p) for i, p in enumerate(PAPER_MACHINES)
        ]
        server = TDAServer(providers, homogenize=homogenize)
        client = ThinClient(server)
        # Warm-up jobs let heartbeats teach the server the true perfs.
        for _ in range(4):
            out, t = client.matmul(a, b)
        return out, t

    out_h, t_h = run(True)
    out_e, t_e = run(False)
    np.testing.assert_allclose(out_h, out_e, rtol=1e-6)
    assert t_h < t_e, (t_h, t_e)


def test_tda_granulation_covers_rows_exactly():
    providers = [ServiceProvider(f"sp{i}", p) for i, p in enumerate([3.0, 2.0, 1.0])]
    server = TDAServer(providers)
    _, reqs, plan = server.granulize(120)
    covered = sorted(r for req in reqs for r in range(req.row_start, req.row_stop))
    assert covered == list(range(120))
    assert sum(plan.shares) == 120
