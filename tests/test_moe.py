"""MoE routing invariants + homogenized expert capacity (the paper's technique
at expert granularity).  Property sweeps use deterministic seeded rng draws
(no hypothesis offline), same envelopes as the old strategies."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import LayerSpec, ModelConfig, MoEConfig
from repro.models.moe import (
    apply_moe,
    apply_moe_dense,
    capacity_per_expert,
    init_moe,
)


def mk_cfg(e=8, k=2, cap=4.0, shared=0) -> ModelConfig:
    return ModelConfig(
        name="moe-test", n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab_size=64, head_dim=16,
        layer_pattern=(LayerSpec("attn", "moe"),),
        moe=MoEConfig(n_routed=e, top_k=k, d_expert=32, capacity_factor=cap,
                      n_shared=shared, d_shared=64 if shared else 0),
        param_dtype="float32", compute_dtype="float32", use_pallas=False,
    )


def _x(b=2, s=16, d=32, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).standard_normal((b, s, d)) * 0.5, jnp.float32
    )


def test_capacity_vs_dense_parity_no_drops():
    """With generous capacity, the capacity-routed path equals the dense sweep."""
    cfg = mk_cfg(cap=8.0)
    p = init_moe(jax.random.key(0), cfg)
    x = _x()
    out_cap, _ = apply_moe(p, cfg, x)
    out_dense, _ = apply_moe_dense(p, cfg, x)
    np.testing.assert_allclose(
        np.asarray(out_cap), np.asarray(out_dense), rtol=2e-4, atol=2e-5
    )


def test_drops_under_tight_capacity():
    cfg = mk_cfg(cap=0.25)
    p = init_moe(jax.random.key(0), cfg)
    x = _x()
    out_tight, _ = apply_moe(p, cfg, x)
    out_dense, _ = apply_moe_dense(p, cfg, x)
    assert float(jnp.max(jnp.abs(out_tight - out_dense))) > 1e-4


def test_aux_loss_positive_and_bounded():
    cfg = mk_cfg()
    p = init_moe(jax.random.key(0), cfg)
    _, aux = apply_moe(p, cfg, _x())
    assert 0 <= float(aux) < 1.0


def test_shared_expert_contributes():
    cfg = mk_cfg(shared=1)
    p = init_moe(jax.random.key(0), cfg)
    x = _x()
    out, _ = apply_moe(p, cfg, x)
    p2 = dict(p)
    p2["shared"] = jax.tree.map(jnp.zeros_like, p["shared"])
    out2, _ = apply_moe(p2, cfg, x)
    assert float(jnp.max(jnp.abs(out - out2))) > 1e-5


# ------------------------------------------------- homogenized capacities
def test_capacity_per_expert_uniform():
    cfg = mk_cfg(e=8, k=2, cap=1.0)
    caps = capacity_per_expert(256, cfg.moe)
    assert (caps == caps[0]).all()
    assert caps.sum() >= 256 * 2


def _rand_capacity_case(seed: int) -> tuple[list[float], int]:
    rng = np.random.default_rng(seed)
    size = int(rng.integers(4, 17))
    perfs = rng.uniform(0.2, 4.0, size).tolist()
    tokens = int(rng.integers(64, 4097))
    return perfs, tokens


@pytest.mark.parametrize(
    "perfs,tokens",
    [_rand_capacity_case(s) for s in range(12)]
    + [
        ([0.2] * 4, 64),              # smallest envelope corner
        ([4.0] * 16, 4096),           # largest
        ([0.2, 4.0, 0.2, 4.0], 64),   # 20:1 spread, few tokens
        ([0.2] * 15 + [4.0], 4096),   # one fast expert among crawlers
    ],
)
def test_capacity_proportional_to_perf(perfs, tokens):
    cfg = mk_cfg(e=len(perfs), k=2, cap=1.0)
    caps = capacity_per_expert(tokens, cfg.moe, expert_perfs=perfs, round_to=1)
    budget = int(cfg.moe.capacity_factor * tokens * cfg.moe.top_k)
    exact = np.asarray(perfs) / np.sum(perfs) * budget
    assert np.all(np.abs(caps - np.maximum(exact, 1)) <= np.maximum(exact, 1) + 1)


def test_homogenized_capacity_equalizes_finish_time():
    cfg = mk_cfg(e=4, k=2, cap=1.0)
    perfs = [4.0, 2.0, 1.0, 0.5]
    caps = capacity_per_expert(512, cfg.moe, expert_perfs=perfs, round_to=1)
    ft = [c / p for c, p in zip(caps, perfs, strict=True)]
    assert max(ft) / min(ft) < 1.15, (caps, ft)


def test_homogenized_capacities_run_through_layer():
    cfg = mk_cfg(e=4, k=2, cap=1.0)
    p = init_moe(jax.random.key(1), cfg)
    caps = capacity_per_expert(32, cfg.moe, expert_perfs=[4.0, 2.0, 1.0, 0.5])
    out, aux = apply_moe(p, cfg, _x(b=2, s=16), jnp.asarray(caps, jnp.int32))
    assert np.isfinite(np.asarray(out)).all()


def test_router_gradient_flows():
    cfg = mk_cfg()
    p = init_moe(jax.random.key(0), cfg)
    x = _x()

    def loss(params):
        out, aux = apply_moe(params, cfg, x)
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(p)
    assert float(jnp.abs(g["router"]).sum()) > 0
    assert float(jnp.abs(g["w_down"]).sum()) > 0
