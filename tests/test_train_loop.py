"""End-to-end training: loss decreases; HDP homogenization, stragglers,
elasticity, checkpoint/restart recovery.

Compile-heavy integration (~35s of jit): out of the tier-1 default run,
exercised via `pytest -m slow` (see pytest.ini)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.core import OverheadModel
from repro.data import GrainSpec, SyntheticSource, batch_from_grains
from repro.models import LayerSpec, Model, ModelConfig
from repro.optim import AdamWConfig
from repro.train import HDPConfig, HDPTrainer, Pod, train_single


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab_size=64, head_dim=16,
        layer_pattern=(LayerSpec("attn", "dense"),),
        param_dtype="float32", compute_dtype="float32", use_pallas=False,
        rope_theta=1e4,
    )
    base.update(kw)
    return ModelConfig(**base)


def _memorize_batch(seq=8, batch=8, vocab=64):
    """A fixed batch the model can memorize — loss must fall fast."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, vocab, (batch, seq + 1))
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "targets": jnp.asarray(toks[:, 1:], jnp.int32),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }


OPT = AdamWConfig(peak_lr=3e-3, min_lr=3e-4, warmup_steps=5, decay_steps=500,
                  weight_decay=0.0)


def test_single_worker_loss_decreases():
    model = Model(tiny_cfg())
    batch = _memorize_batch()
    state, hist = train_single(
        model, 60, lambda s: batch, opt_cfg=OPT, log_every=1
    )
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7, (hist[0], hist[-1])
    assert np.isfinite(hist[-1]["loss"])


def test_single_worker_checkpoint_restart_exact(tmp_path):
    model = Model(tiny_cfg())
    batch = _memorize_batch()
    d = str(tmp_path / "ck")
    # run 20 steps with checkpoint every 10
    state_a, _ = train_single(model, 20, lambda s: batch, opt_cfg=OPT,
                              ckpt_dir=d, ckpt_every=10, log_every=5)
    # "crash" after step 20, resume to 30
    state_b, _ = train_single(model, 30, lambda s: batch, opt_cfg=OPT,
                              ckpt_dir=d, ckpt_every=10, log_every=5)
    # independent run straight to 30 must match exactly (same batches, same seed)
    state_c, _ = train_single(model, 30, lambda s: batch, opt_cfg=OPT, log_every=5)
    for b, c in zip(jax.tree.leaves(state_b.params), jax.tree.leaves(state_c.params), strict=True):
        np.testing.assert_allclose(np.asarray(b), np.asarray(c), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------------- HDP
def _hdp(pods, homogenize=True, **kw):
    model = Model(tiny_cfg())
    spec = GrainSpec(grain_size=1, seq_len=8, vocab_size=64)
    cfg = HDPConfig(
        total_grains=8, grain_spec=spec, homogenize=homogenize,
        overhead=OverheadModel(m=2.0), **kw,
    )
    return HDPTrainer(model, pods, cfg, opt_cfg=OPT)


def test_hdp_loss_decreases_and_plans_proportional():
    tr = _hdp([Pod("fast", 4.0), Pod("slow", 1.0)])
    hist = tr.run(25)
    assert hist[-1]["loss"] < hist[0]["loss"]
    plan = hist[-1]["plan"]
    # After heartbeats converge, fast pod carries ~4x the grains.
    assert plan["fast"] >= 3 * plan["slow"], plan


def test_hdp_homogenized_faster_than_equal_split():
    h = _hdp([Pod("a", 4.0), Pod("b", 1.0)], homogenize=True).run(20)
    e = _hdp([Pod("a", 4.0), Pod("b", 1.0)], homogenize=False).run(20)
    t_h = sum(r["step_time"] for r in h[5:])   # skip learning transient
    t_e = sum(r["step_time"] for r in e[5:])
    assert t_h < t_e, (t_h, t_e)


def test_hdp_equal_perf_equal_plan():
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0)])
    hist = tr.run(10)
    plan = hist[-1]["plan"]
    assert plan["a"] == plan["b"]


def test_hdp_straggler_mitigation():
    """A pod that slows mid-run must lose grains within a few steps."""
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0)])
    tr.run(10)
    assert tr.history[-1]["plan"]["a"] == tr.history[-1]["plan"]["b"]
    tr.set_perf("a", 0.4)  # 5x slowdown (thermal throttle / noisy neighbor)
    for s in range(10, 22):
        tr.step(s)
    plan = tr.history[-1]["plan"]
    assert plan["a"] < plan["b"], plan


def test_hdp_elastic_pod_death():
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0), Pod("c", 2.0)])
    tr.run(5)
    tr.kill("c")
    for s in range(5, 10):
        tr.step(s)
    plan = tr.history[-1]["plan"]
    assert "c" not in plan
    assert sum(plan.values()) == 8  # all grains redistributed
    assert np.isfinite(tr.history[-1]["loss"])


def test_hdp_checkpoint_restart(tmp_path):
    d = str(tmp_path / "hdp")
    tr1 = _hdp([Pod("a", 3.0), Pod("b", 1.0)], ckpt_dir=d, ckpt_every=5)
    tr1.run(10)
    # new trainer (fresh process) resumes from step 10
    tr2 = _hdp([Pod("a", 3.0), Pod("b", 1.0)], ckpt_dir=d, ckpt_every=5)
    assert tr2.start_step == 10
    tr2.run(15)
    assert len(tr2.history) == 5


def test_hdp_grad_compression_still_learns():
    tr = _hdp([Pod("a", 2.0), Pod("b", 1.0)], compress_grads=True)
    hist = tr.run(25)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_hdp_weighted_combine_matches_single_worker():
    """With equal perfs and no compression, HDP over 2 pods must equal a
    single-worker run over the concatenated batch (weighted-combine check)."""
    model = Model(tiny_cfg())
    spec = GrainSpec(grain_size=1, seq_len=8, vocab_size=64)
    cfg = HDPConfig(total_grains=4, grain_spec=spec,
                    overhead=OverheadModel(m=2.0))
    tr = HDPTrainer(model, [Pod("a", 1.0), Pod("b", 1.0)], cfg, opt_cfg=OPT)
    tr.step(0)
    # single-worker equivalent: all 4 grains in one batch
    src = SyntheticSource(spec, seed=cfg.seed)
    batch = batch_from_grains(src, 0, [0, 1, 2, 3], spec)
    model2 = Model(tiny_cfg())
    from repro.train import init_train_state
    from repro.optim import adamw_update

    state = init_train_state(model2.init(jax.random.key(cfg.seed)))
    (loss, _), grads = jax.value_and_grad(
        lambda p, b: model2.loss(p, b), has_aux=True
    )(state.params, batch)
    new_params, _, _ = adamw_update(grads, state.opt, state.params, OPT)
    for a, b in zip(jax.tree.leaves(tr.state.params), jax.tree.leaves(new_params), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5)
