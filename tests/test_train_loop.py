"""End-to-end training: loss decreases; HDP homogenization, stragglers,
elasticity, checkpoint/restart recovery.

Compile-heavy integration (~35s of jit): out of the tier-1 default run,
exercised via `pytest -m slow` (see pytest.ini)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = pytest.mark.slow

from repro.core import OverheadModel, TimelineEvent
from repro.data import GrainSpec, SyntheticSource, batch_from_grains
from repro.models import LayerSpec, Model, ModelConfig
from repro.optim import AdamWConfig
from repro.train import HDPConfig, HDPTrainer, Pod, train_single


def tiny_cfg(**kw) -> ModelConfig:
    base = dict(
        name="tiny", n_layers=2, d_model=32, n_heads=2, n_kv_heads=2,
        d_ff=64, vocab_size=64, head_dim=16,
        layer_pattern=(LayerSpec("attn", "dense"),),
        param_dtype="float32", compute_dtype="float32", use_pallas=False,
        rope_theta=1e4,
    )
    base.update(kw)
    return ModelConfig(**base)


def _memorize_batch(seq=8, batch=8, vocab=64):
    """A fixed batch the model can memorize — loss must fall fast."""
    rng = np.random.default_rng(0)
    toks = rng.integers(0, vocab, (batch, seq + 1))
    return {
        "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
        "targets": jnp.asarray(toks[:, 1:], jnp.int32),
        "loss_mask": jnp.ones((batch, seq), jnp.float32),
    }


OPT = AdamWConfig(peak_lr=3e-3, min_lr=3e-4, warmup_steps=5, decay_steps=500,
                  weight_decay=0.0)


def test_single_worker_loss_decreases():
    model = Model(tiny_cfg())
    batch = _memorize_batch()
    state, hist = train_single(
        model, 60, lambda s: batch, opt_cfg=OPT, log_every=1
    )
    assert hist[-1]["loss"] < hist[0]["loss"] * 0.7, (hist[0], hist[-1])
    assert np.isfinite(hist[-1]["loss"])


def test_single_worker_checkpoint_restart_exact(tmp_path):
    model = Model(tiny_cfg())
    batch = _memorize_batch()
    d = str(tmp_path / "ck")
    # run 20 steps with checkpoint every 10
    state_a, _ = train_single(model, 20, lambda s: batch, opt_cfg=OPT,
                              ckpt_dir=d, ckpt_every=10, log_every=5)
    # "crash" after step 20, resume to 30
    state_b, _ = train_single(model, 30, lambda s: batch, opt_cfg=OPT,
                              ckpt_dir=d, ckpt_every=10, log_every=5)
    # independent run straight to 30 must match exactly (same batches, same seed)
    state_c, _ = train_single(model, 30, lambda s: batch, opt_cfg=OPT, log_every=5)
    for b, c in zip(jax.tree.leaves(state_b.params), jax.tree.leaves(state_c.params), strict=True):
        np.testing.assert_allclose(np.asarray(b), np.asarray(c), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------------------- HDP
def _hdp(pods, homogenize=True, total_grains=8, **kw):
    model = Model(tiny_cfg())
    spec = GrainSpec(grain_size=1, seq_len=8, vocab_size=64)
    cfg = HDPConfig(
        total_grains=total_grains, grain_spec=spec, homogenize=homogenize,
        overhead=OverheadModel(m=2.0), **kw,
    )
    return HDPTrainer(model, pods, cfg, opt_cfg=OPT)


def test_hdp_loss_decreases_and_plans_proportional():
    tr = _hdp([Pod("fast", 4.0), Pod("slow", 1.0)])
    hist = tr.run(25)
    assert hist[-1]["loss"] < hist[0]["loss"]
    plan = hist[-1]["plan"]
    # After heartbeats converge, fast pod carries ~4x the grains.
    assert plan["fast"] >= 3 * plan["slow"], plan


def test_hdp_homogenized_faster_than_equal_split():
    h = _hdp([Pod("a", 4.0), Pod("b", 1.0)], homogenize=True).run(20)
    e = _hdp([Pod("a", 4.0), Pod("b", 1.0)], homogenize=False).run(20)
    t_h = sum(r["step_time"] for r in h[5:])   # skip learning transient
    t_e = sum(r["step_time"] for r in e[5:])
    assert t_h < t_e, (t_h, t_e)


def test_hdp_equal_perf_equal_plan():
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0)])
    hist = tr.run(10)
    plan = hist[-1]["plan"]
    assert plan["a"] == plan["b"]


def test_hdp_straggler_mitigation():
    """A pod that slows mid-run must lose grains within a few steps."""
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0)])
    tr.run(10)
    assert tr.history[-1]["plan"]["a"] == tr.history[-1]["plan"]["b"]
    tr.set_perf("a", 0.4)  # 5x slowdown (thermal throttle / noisy neighbor)
    for s in range(10, 22):
        tr.step(s)
    plan = tr.history[-1]["plan"]
    assert plan["a"] < plan["b"], plan


def test_hdp_elastic_pod_death():
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0), Pod("c", 2.0)])
    tr.run(5)
    tr.kill("c")
    for s in range(5, 10):
        tr.step(s)
    plan = tr.history[-1]["plan"]
    assert "c" not in plan
    assert sum(plan.values()) == 8  # all grains redistributed
    assert np.isfinite(tr.history[-1]["loss"])


def test_hdp_checkpoint_restart(tmp_path):
    d = str(tmp_path / "hdp")
    tr1 = _hdp([Pod("a", 3.0), Pod("b", 1.0)], ckpt_dir=d, ckpt_every=5)
    tr1.run(10)
    # new trainer (fresh process) resumes from step 10
    tr2 = _hdp([Pod("a", 3.0), Pod("b", 1.0)], ckpt_dir=d, ckpt_every=5)
    assert tr2.start_step == 10
    tr2.run(15)
    assert len(tr2.history) == 5


def test_hdp_grad_compression_still_learns():
    tr = _hdp([Pod("a", 2.0), Pod("b", 1.0)], compress_grads=True)
    hist = tr.run(25)
    assert hist[-1]["loss"] < hist[0]["loss"]


def test_hdp_adaptive_and_static_are_bitwise_identical():
    """The tentpole numerics invariant: grain→pod assignment only changes
    timing, never data.  With no timeline events, the runtime-driven adaptive
    path and the static per-step plan produce bitwise-identical loss,
    grad_norm and parameters."""
    a = _hdp([Pod("fast", 4.0), Pod("slow", 1.0)], adaptive=True)
    b = _hdp([Pod("fast", 4.0), Pod("slow", 1.0)], adaptive=False)
    for s in range(3):
        ra, rb = a.step(s), b.step(s)
        assert ra["loss"] == rb["loss"]            # bitwise, not approx
        assert ra["grad_norm"] == rb["grad_norm"]
    for x, y in zip(jax.tree.leaves(a.state.params),
                    jax.tree.leaves(b.state.params), strict=True):
        assert np.array_equal(np.asarray(x), np.asarray(y))


def _midstep_halving(adaptive: bool):
    """Scripted mid-step perf-halving on one pod (ISSUE acceptance)."""
    tr = _hdp([Pod(f"p{i}", 2.0) for i in range(4)], adaptive=adaptive,
              total_grains=32)
    for s in range(2):
        tr.step(s)                      # heartbeats converge to true perfs
    est_makespan = 32 / 8.0
    tr.schedule(TimelineEvent(tr.clock + 0.25 * est_makespan, "perf", "p0",
                              perf=1.0))
    return tr.step(2)


def test_hdp_midstep_perf_halving_acceptance():
    """Runtime-driven trainer holds the homogenization line through a
    mid-step slowdown (quality <= 1.2); the static per-step plan drags at the
    straggler's pace (>= 1.6) on the same timeline."""
    ad = _midstep_halving(adaptive=True)
    st = _midstep_halving(adaptive=False)
    assert ad["quality"] <= 1.2, ad
    assert st["quality"] >= 1.6, st
    assert ad["step_time"] < st["step_time"]
    assert ad["n_migrated"] > 0
    # identical data => identical numerics even across the fault
    assert ad["loss"] == st["loss"]
    assert ad["grad_norm"] == st["grad_norm"]


def test_hdp_midstep_kill_completes_step_and_stays_dead():
    """A pod killed mid-step: its unfinished grains re-home, the step
    completes, and the pod stays out of later plans (no resurrection)."""
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0), Pod("c", 2.0)], total_grains=12)
    tr.step(0)
    tr.schedule(TimelineEvent(tr.clock + 0.5, "kill", "c"))
    rec = tr.step(1)
    assert rec["tokens"] == 12 * 8            # every grain exactly once
    assert not tr.pods["c"].alive
    rec2 = tr.step(2)
    assert "c" not in rec2["plan"]
    assert np.isfinite(rec2["loss"])


def test_hdp_midstep_rejoin_replaces_killed_pod():
    """A timeline 'join' of a previously-killed pod must replace the stale
    dead Pod in the trainer's fleet view, so set_perf/alive hit the object
    the runtime actually schedules."""
    tr = _hdp([Pod("a", 2.0), Pod("b", 2.0)])
    tr.step(0)
    tr.kill("b")
    tr.step(1)
    tr.schedule(TimelineEvent(tr.clock + 0.1, "join", Pod("b", 2.0)))
    rec = tr.step(2)
    assert tr.pods["b"].alive
    assert tr.pods["b"] is tr.runtime.workers["b"]
    assert rec["plan"].get("b", 0) > 0 or tr.step(3)["plan"].get("b", 0) > 0
    tr.set_perf("b", 0.5)                    # must mutate the live object
    assert tr.runtime.workers["b"].perf == 0.5


def test_cluster_train_facade_dsl_halving_acceptance():
    """The ISSUE 4 train-side acceptance through the declarative facade: a
    DSL-scripted mid-step perf halving holds adaptive homogenization quality
    <= 1.3 (static >= 1.6 on the same Scenario), with identical numerics."""
    from repro.cluster import Cluster, FleetSpec, TrainJob

    fleet = FleetSpec.parse("p0=2,p1=2,p2=2,p3=2")
    model = Model(tiny_cfg())

    def run(adaptive):
        job = TrainJob(model, steps=3, grains=32, seq_len=8, vocab_size=64,
                       opt=OPT)
        return Cluster(fleet, adaptive=adaptive).train(
            job, scenario="halve:p0@2:25%")

    ad, st = run(True), run(False)
    fa, fs = ad.phases[2], st.phases[2]
    assert fa.quality <= 1.3, ad.summary()
    assert fs.quality >= 1.6, st.summary()
    assert fa.sim_time_s < fs.sim_time_s
    assert fa.n_migrated > 0
    # identical grain data => identical numerics even across the fault
    assert fa.metrics["loss"] == fs.metrics["loss"]
    assert ad.kind == "train" and ad.scenario == "halve:p0@2:25%"
    assert ad.fleet == str(fleet)
    assert sum(w.n_grains for w in ad.worker_timelines.values()) == 3 * 32
    assert ad.artifact.start_step == 0          # the live trainer rides along


def test_hdp_restart_restores_tracker_and_plan(tmp_path):
    """Kill the coordinator after step k; the restarted one resumes with the
    learned perf vector — its first plan equals the plan the never-killed
    coordinator would produce, and the next step is bitwise identical."""
    d = str(tmp_path / "hdp")
    A = _hdp([Pod("fast", 3.0), Pod("slow", 1.0)], ckpt_dir=d, ckpt_every=2)
    A.run(4)
    B = _hdp([Pod("fast", 3.0), Pod("slow", 1.0)], ckpt_dir=d, ckpt_every=2)
    assert B.start_step == 4
    assert B.tracker.perf_vector(B.clock) == A.tracker.perf_vector(A.clock)
    assert B.plan_preview() == A.plan_preview()
    ra, rb = A.step(4), B.step(4)
    assert ra["loss"] == rb["loss"] and ra["grad_norm"] == rb["grad_norm"]
    assert ra["plan"] == rb["plan"]


def test_hdp_weighted_combine_matches_single_worker():
    """With equal perfs and no compression, HDP over 2 pods must equal a
    single-worker run over the concatenated batch (weighted-combine check)."""
    model = Model(tiny_cfg())
    spec = GrainSpec(grain_size=1, seq_len=8, vocab_size=64)
    cfg = HDPConfig(total_grains=4, grain_spec=spec,
                    overhead=OverheadModel(m=2.0))
    tr = HDPTrainer(model, [Pod("a", 1.0), Pod("b", 1.0)], cfg, opt_cfg=OPT)
    tr.step(0)
    # single-worker equivalent: all 4 grains in one batch
    src = SyntheticSource(spec, seed=cfg.seed)
    batch = batch_from_grains(src, 0, [0, 1, 2, 3], spec)
    model2 = Model(tiny_cfg())
    from repro.train import init_train_state
    from repro.optim import adamw_update

    state = init_train_state(model2.init(jax.random.key(cfg.seed)))
    (loss, _), grads = jax.value_and_grad(
        lambda p, b: model2.loss(p, b), has_aux=True
    )(state.params, batch)
    new_params, _, _ = adamw_update(grads, state.opt, state.params, OPT)
    for a, b in zip(jax.tree.leaves(tr.state.params), jax.tree.leaves(new_params), strict=True):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=1e-5)
